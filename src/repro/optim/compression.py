"""Gradient compression with error feedback — the paper's §3.2.1
("compress what you ship") applied to the training substrate.

int8 symmetric quantization per leaf with a per-leaf f32 scale; the
quantization residual is carried in an error-feedback buffer and added to
the next step's gradient, preserving convergence (Karimireddy et al. 2019).
Intended use: quantize BEFORE the cross-pod reduction (the slow axis),
reduce in int-as-float, dequantize after — the dry-run's collective-bytes
accounting shows the 4x shrink on the ``pod`` axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: object  # pytree of f32 residuals, like grads


def compression_init(grads_shape_tree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                           grads_shape_tree)
    )


def _quant(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_gradients(grads, state: CompressionState):
    """Returns (quantized tree of (int8, scale), new_state with residuals)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = _quant(g)
        resid = g - _dequant(q, s)
        return (q, s), resid

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = treedef.unflatten([p[0] for p in pairs])
    etree = treedef.unflatten([p[1] for p in pairs])
    return qtree, CompressionState(error=etree)


def decompress_gradients(qtree):
    return jax.tree.map(
        lambda qs: _dequant(qs[0], qs[1]),
        qtree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and not isinstance(x[0], tuple),
    )
