"""AdamW built in-house (no optax dependency): decoupled weight decay,
global-norm clipping, linear warmup + cosine decay schedule.

Optimizer state shards exactly like the parameters (same logical axes), so
the checkpointing layer treats (params, opt_state) uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object       # pytree like params
    nu: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def adamw_init(params) -> AdamWState:
    zeros = lambda tree: jax.tree.map(jnp.zeros_like, tree)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
