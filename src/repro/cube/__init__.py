"""Two-tier rollup-cube subsystem.

Tier 1: dense rollup cubes, pre-aggregated in ONE distributed scan per cube
by a precompiled SPMD plan (``build``), served from host memory in
microseconds (``router``).  Tier 2: the engine's precompiled per-query plans
over the sharded base tables — the fallback for queries no cube covers.

  spec    CubeSpec / Dimension / Measure declarations
  build   distributed single-pass builder (shard_map + psum/pmin/pmax)
  router  query matcher: covering-rollup selection, slice/marginalize, or
          route to Tier 2
"""
from repro.cube.spec import CubeSpec, Dimension, Measure  # noqa: F401
from repro.cube.build import Cube, build_cube, make_build_plan  # noqa: F401
from repro.cube.router import (  # noqa: F401
    AggQuery,
    CubeRouter,
    Filter,
    Match,
    Route,
    derive_agg_query,
)
