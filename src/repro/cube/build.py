"""Distributed single-pass rollup-cube builder (Tier-1 materialization).

The build is one precompiled SPMD plan in the engine's own model
(``Cluster.compile`` → shard_map → jit): every node scans its partition of
the base table once, computes a dense partial aggregate over the cube's
composite key space with the engine's local-aggregation substrate
(one-hot MXU contraction / dense scatter-add / the fused Pallas
``grouped_agg`` kernel), and the partials are merged with one collective
reduce per aggregate kind (``psum`` for sum/count, ``pmin``/``pmax`` for
min/max) — the paper's "custom reduce operator merges the partial result
sets", §3.2.3.  Coarser rollups are marginals of the finest and are derived
inside the same compiled plan, so N rollups cost ONE scan of the sharded
columns.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import aggregation, exchange
from repro.cube.spec import CubeSpec

ROWS = "__rows"  # internal per-cell row count, present in every rollup


def rollup_key(dims) -> str:
    return ",".join(dims)


def _codes(dim, cols):
    col = cols[dim.column]
    if dim.binned:
        edges = jnp.asarray(dim.edges, col.dtype)
        return jnp.searchsorted(edges, col, side="left").astype(jnp.int32)
    return jnp.clip(col.astype(jnp.int32), 0, dim.cardinality - 1)


def _measure_values(measure, cols):
    from repro.query.ir import Expr, eval_expr

    if measure.agg == "count":
        n = next(iter(cols.values())).shape[0]
        return jnp.ones(n, jnp.float32)
    if isinstance(measure.column, Expr):
        col = eval_expr(measure.column, cols)
    elif callable(measure.column):
        col = measure.column(cols)
    else:
        col = cols[measure.column]
    return col.astype(jnp.float32)


def _local_sums(spec, key, stacked, num_cells):
    """(G, C) partial sums for the sum/count measure stack."""
    method = spec.resolve_method()
    if method == "kernel":
        from repro.kernels import ops

        if num_cells > spec.KERNEL_MAX_GROUPS:
            raise ValueError(
                f"cube {spec.name}: {num_cells} cells exceeds the kernel limit "
                f"{spec.KERNEL_MAX_GROUPS}"
            )
        pred = jnp.zeros(key.shape[0], jnp.int32)  # no build-time predicate
        return ops.filtered_group_sum(
            stacked, key, pred, cutoff=0, num_groups=num_cells
        )
    if method == "onehot":
        return aggregation.group_sum_onehot(stacked, key, num_cells)
    # dense scatter-add, one column at a time (large key spaces)
    outs = [
        aggregation.group_sum_dense(stacked[:, c], key, num_cells)
        for c in range(stacked.shape[1])
    ]
    return jnp.stack(outs, axis=1)


def make_build_plan(spec: CubeSpec):
    """Plan(ctx, tables) -> {rollup_key: {measure: dense array}} — runs inside
    shard_map; all outputs are replicated (every node holds the full cube,
    exactly like a plan's result rows)."""

    sum_like = [m for m in spec.measures if m.agg in ("sum", "count")]
    minmax = [m for m in spec.measures if m.agg in ("min", "max")]
    if spec.resolve_method() == "kernel" and minmax:
        raise ValueError(
            f"cube {spec.name}: the grouped_agg kernel path supports only "
            f"sum/count measures"
        )

    def plan(ctx, t):
        cols = t[spec.table]
        codes = [_codes(d, cols) for d in spec.dimensions]
        key = codes[0]
        for d, c in zip(spec.dimensions[1:], codes[1:]):
            key = key * d.cardinality + c
        G = spec.num_cells

        # one scan: sums/counts as a stacked (n, C) pass + a rows column
        stacked = jnp.stack(
            [_measure_values(m, cols) for m in sum_like]
            + [jnp.ones(key.shape[0], jnp.float32)],
            axis=1,
        )
        sums = exchange.allreduce_sum(_local_sums(spec, key, stacked, G), ctx.axis)

        finest = {}
        for i, m in enumerate(sum_like):
            finest[m.name] = sums[:, i].reshape(spec.shape)
        finest[ROWS] = sums[:, len(sum_like)].reshape(spec.shape)

        # min/max: dense scatter with sentinel init, merged with pmin/pmax
        for m in minmax:
            v = _measure_values(m, cols)
            sentinel = jnp.inf if m.agg == "min" else -jnp.inf
            init = jnp.full(G, sentinel, jnp.float32)
            local = init.at[key].min(v) if m.agg == "min" else init.at[key].max(v)
            merged = (
                exchange.allreduce_min(local, ctx.axis)
                if m.agg == "min"
                else exchange.allreduce_max(local, ctx.axis)
            )
            finest[m.name] = merged.reshape(spec.shape)

        # coarser rollups: marginalize the finest inside the same executable
        out = {}
        for rollup in spec.rollups:
            axes = tuple(
                i for i, d in enumerate(spec.dimensions) if d.name not in rollup
            )
            arrays = {}
            for name, arr in finest.items():
                agg = _agg_of(spec, name)
                if not axes:
                    arrays[name] = arr
                elif agg in ("sum", "count"):
                    arrays[name] = jnp.sum(arr, axis=axes)
                elif agg == "min":
                    arrays[name] = jnp.min(arr, axis=axes)
                else:
                    arrays[name] = jnp.max(arr, axis=axes)
            out[rollup_key(rollup)] = arrays
        return out

    return plan


def _agg_of(spec: CubeSpec, measure_name: str) -> str:
    if measure_name == ROWS:
        return "count"
    for m in spec.measures:
        if m.name == measure_name:
            return m.agg
    raise KeyError(measure_name)


@dataclasses.dataclass
class Cube:
    """A built cube: host-resident dense rollup arrays, served in-process.

    rollups: dim-name tuple (spec order) -> {measure name: np.ndarray whose
    axes follow the dim tuple}.  Empty cells hold 0 for sum/count and
    +/-inf sentinels for min/max (``rows`` distinguishes truly-empty cells).
    """

    spec: CubeSpec
    rollups: dict
    build_seconds: float = 0.0
    rows_scanned: int = 0

    def rollup(self, dims) -> Mapping[str, np.ndarray]:
        return self.rollups[tuple(dims)]

    @property
    def num_values(self) -> int:
        return sum(
            a.size for r in self.rollups.values() for a in r.values()
        )


def build_cube(cluster, ctx, placed, spec: CubeSpec) -> Cube:
    """Compile + run the build plan over already-placed tables (the driver's
    ``self.placed``); returns the host-side ``Cube``."""
    plan = make_build_plan(spec)
    fn = cluster.compile(plan, ctx, placed)
    columns = {n: t.columns for n, t in placed.items()}
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(columns))
    dt = time.perf_counter() - t0
    rollups = {}
    # spec.rollups entries are name tuples in declaration order; arrays follow
    # the SPEC order of those dims (marginalization preserves axis order)
    for rollup in spec.rollups:
        ordered = tuple(n for n in spec.dim_names if n in rollup)
        rollups[ordered] = {
            name: np.asarray(arr) for name, arr in out[rollup_key(rollup)].items()
        }
    nrows = placed[spec.table].num_rows
    return Cube(spec=spec, rollups=rollups, build_seconds=dt, rows_scanned=nrows)
