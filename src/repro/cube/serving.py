"""Shared two-tier measurement protocol.

One implementation of "time Tier 1 vs Tier 2 for a query", used by both
``launch/serve_olap.py --cubes`` and ``benchmarks/cube_speedup.py`` so the
two reports can't drift.  The query is ONE IR object: Tier 1 is the
router's host-side rollup slice (best-of-N, N floored at 10 because a
single slice is microseconds); Tier 2 is the SAME query lowered to a
compiled SPMD plan over the base tables — the path ``driver.query()``
takes on a cube miss — warm, best-of-``repeat``.
"""
from __future__ import annotations

import time


def measure_query(driver, q, *, repeat: int = 5):
    """Time one cube-covered IR query on both tiers.

    Returns ``{"route", "tier1_s", "tier2_s", "plan"}``, or None when no
    rollup covers the query (Tier 2 only — nothing to compare).
    """
    import jax

    match = driver.router.route_query(q) if driver.router is not None else None
    if match is None:
        return None
    cols = {n: t.columns for n, t in driver.placed.items()}

    driver.router.answer(match.query, match.route)  # warmup (numpy setup)
    t1 = min(_clock(lambda: driver.router.answer(match.query, match.route))
             for _ in range(max(repeat, 10)))

    # Tier 2 is the same query lowered to a compiled SPMD plan — exactly
    # what driver.query() would run on a cube miss
    fn = driver.compile_query(q)
    plan_name = f"{q.name or 'ir'} (lowered)"
    jax.block_until_ready(fn(cols))  # warmup (first execute compiles)
    t2 = min(_clock(lambda: jax.block_until_ready(fn(cols)))
             for _ in range(max(repeat, 3)))
    return {
        "route": match.route,
        "tier1_s": t1,
        "tier2_s": t2,
        "plan": plan_name,
    }


def _clock(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
