"""Shared two-tier measurement protocol.

One implementation of "time Tier 1 vs Tier 2 for a cube query", used by
both ``launch/serve_olap.py --cubes`` and ``benchmarks/cube_speedup.py``
so the two reports can't drift.  Tier 1 is the router's host-side rollup
slice (best-of-N, N floored at 10 because a single slice is microseconds);
Tier 2 is the query's fallback precompiled plan, warm, best-of-``repeat``.
A query with no declared fallback is timed against the ``q1`` full-scan
plan as a REPRESENTATIVE Tier-2 cost — ``proxy`` is True in that case and
reports must say so.
"""
from __future__ import annotations

import time


def measure_query(driver, q, *, repeat: int = 5, proxy_plan: str = "q1"):
    """Time one cube-covered AggQuery on both tiers.

    Returns ``{"route", "tier1_s", "tier2_s", "plan", "proxy"}``, or None
    when no rollup covers the query (Tier 2 only — nothing to compare).
    """
    import jax

    route = driver.router.route(q) if driver.router is not None else None
    if route is None:
        return None
    cols = {n: t.columns for n, t in driver.placed.items()}

    driver.router.answer(q, route)  # warmup (numpy one-time setup)
    t1 = min(_clock(lambda: driver.router.answer(q, route))
             for _ in range(max(repeat, 10)))

    plan = q.fallback or proxy_plan
    fn = driver.compile(plan)
    jax.block_until_ready(fn(cols))  # warmup (first execute compiles)
    t2 = min(_clock(lambda: jax.block_until_ready(fn(cols)))
             for _ in range(max(repeat, 3)))
    return {
        "route": route,
        "tier1_s": t1,
        "tier2_s": t2,
        "plan": plan,
        "proxy": q.fallback is None,
    }


def _clock(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
