"""Shared two-tier measurement protocol.

One implementation of "time Tier 1 vs Tier 2 for a query", used by both
``launch/serve_olap.py --cubes`` and ``benchmarks/cube_speedup.py`` so the
two reports can't drift.  The query is ONE IR object: Tier 1 is the
router's host-side rollup slice (N floored at 10 because a single slice is
microseconds); Tier 2 is the SAME query lowered to a compiled SPMD plan
over the base tables — the path ``driver.query()`` takes on a cube miss —
warm, over ``repeat`` runs.

Reported statistics are the TRIMMED MEDIAN (drop the top/bottom ~10% of
repeats when there are enough of them, then take the median — robust to
scheduler noise in both directions, unlike min-of-N which reports a best
case no serving tier sustains) and the p99 tail.  Every repeat is also recorded into the
driver's metrics registry (``serving.tier1_us`` / ``serving.tier2_us``
histograms) so ``--metrics`` reports cross-query percentiles.
"""
from __future__ import annotations

import time


def _trimmed_median(samples) -> float:
    """Median after dropping the top/bottom ~10% of samples (one sample
    each end per 10, only when n >= 5 so tiny repeat counts keep every
    run).  The trim makes the reported center insensitive to warmup or
    preemption outliers even at small n."""
    xs = sorted(samples)
    k = len(xs) // 10 if len(xs) >= 10 else (1 if len(xs) >= 5 else 0)
    xs = xs[k:len(xs) - k] if k else xs
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def _p99(samples) -> float:
    xs = sorted(samples)
    return xs[min(len(xs) - 1, int(0.99 * len(xs)))]


def measure_query(driver, q, *, repeat: int = 5):
    """Time one cube-covered IR query on both tiers.

    Returns ``{"route", "tier1_s", "tier2_s", "tier1_p99_s",
    "tier2_p99_s", "plan"}``, or None when no rollup covers the query
    (Tier 2 only — nothing to compare).  ``tier1_s``/``tier2_s`` are
    trimmed medians over the repeats; ``*_p99_s`` the observed tails.
    """
    import jax

    match = driver.router.route_query(q) if driver.router is not None else None
    if match is None:
        return None
    cols = {n: t.columns for n, t in driver.placed.items()}

    driver.router.answer(match.query, match.route)  # warmup (numpy setup)
    s1 = [_clock(lambda: driver.router.answer(match.query, match.route))
          for _ in range(max(repeat, 10))]

    # Tier 2 is the same query lowered to a compiled SPMD plan — exactly
    # what driver.query() would run on a cube miss
    fn = driver.compile_query(q)
    plan_name = f"{q.name or 'ir'} (lowered)"
    jax.block_until_ready(fn(cols))  # warmup (first execute compiles)
    s2 = [_clock(lambda: jax.block_until_ready(fn(cols)))
          for _ in range(max(repeat, 3))]

    obs = getattr(driver, "obs", None)
    if obs is not None and obs.metrics is not None:
        h1 = obs.metrics.histogram("serving.tier1_us")
        h2 = obs.metrics.histogram("serving.tier2_us")
        for s in s1:
            h1.record(s * 1e6)
        for s in s2:
            h2.record(s * 1e6)

    return {
        "route": match.route,
        "tier1_s": _trimmed_median(s1),
        "tier2_s": _trimmed_median(s2),
        "tier1_p99_s": _p99(s1),
        "tier2_p99_s": _p99(s2),
        "plan": plan_name,
    }


def _clock(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
