"""Cube specifications: what to pre-aggregate, at what granularity.

A ``CubeSpec`` declares, over one named table, a set of *dimensions* (small
integer code spaces) and *measures* (sum/count/min/max of a column), plus the
list of *rollups* (dimension subsets) to materialize.  The builder
(``cube.build``) computes the finest rollup in a single distributed scan and
derives every coarser rollup by marginalization, so the whole spec costs one
pass over the sharded columns.

Dimensions come in two flavors:

- *categorical*: the column already stores dense codes in ``[0, cardinality)``
  (dictionary-encoded strings, small enums).
- *binned*: a numeric column digitized against explicit, sorted ``edges``;
  code ``j`` covers the half-open interval ``(edges[j-1], edges[j]]`` with
  code 0 below the first edge and code ``len(edges)`` above the last.  A
  range predicate is exactly answerable from the cube iff its bound lands on
  an edge — the router checks this and falls back to Tier 2 otherwise.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Dimension:
    """One axis of the cube.

    column: source column name in the base table.
    cardinality: number of distinct codes (categorical dims).
    edges: sorted bin edges for binned numeric dims (overrides cardinality:
        the code space is ``len(edges) + 1``).
    integral: asserts the binned column takes only integer values, letting
        the router rewrite strict bounds (``< v`` -> ``<= v-1``); leave
        False for float domains, where such bounds fall back to Tier 2.
    """

    name: str
    column: str
    cardinality: int = 0
    edges: tuple = ()
    integral: bool = False

    def __post_init__(self):
        if self.edges:
            object.__setattr__(self, "edges", tuple(sorted(self.edges)))
            object.__setattr__(self, "cardinality", len(self.edges) + 1)
        if self.cardinality <= 0:
            raise ValueError(f"dimension {self.name}: cardinality must be set")

    @property
    def binned(self) -> bool:
        return bool(self.edges)


AGGS = ("sum", "count", "min", "max")


@dataclasses.dataclass(frozen=True)
class Measure:
    """One aggregate: ``agg(column)`` per cube cell.

    column may be a plain column name, a ``repro.query`` expression (the
    preferred form — the cube router matches IR measures against it
    structurally), or a legacy callable mapping the local column dict to a
    value array.  ``count`` measures ignore the column.
    """

    name: str
    agg: str
    column: object = None

    def __post_init__(self):
        if self.agg not in AGGS:
            raise ValueError(f"measure {self.name}: unknown agg {self.agg!r}")
        if self.agg != "count" and self.column is None:
            raise ValueError(f"measure {self.name}: agg {self.agg} needs a column")


@dataclasses.dataclass(frozen=True)
class CubeSpec:
    """A named cube over one table.

    rollups: dimension-name subsets to materialize; defaults to the single
    finest rollup over all dimensions.  Every rollup must be a subset of
    ``dimensions`` (the finest rollup is always built — coarser ones are its
    marginals).
    method: local aggregation strategy — "auto" (onehot below
    ``ONEHOT_MAX_GROUPS`` cells else dense scatter-add), "onehot", "dense",
    or "kernel" (the fused Pallas grouped-aggregation kernel; sum/count
    measures only).
    """

    name: str
    table: str
    dimensions: tuple
    measures: tuple
    rollups: tuple = ()
    method: str = "auto"

    ONEHOT_MAX_GROUPS = 8192
    KERNEL_MAX_GROUPS = 512

    def __post_init__(self):
        names = [d.name for d in self.dimensions]
        if len(set(names)) != len(names):
            raise ValueError(f"cube {self.name}: duplicate dimension names")
        mnames = [m.name for m in self.measures]
        if len(set(mnames)) != len(mnames):
            raise ValueError(f"cube {self.name}: duplicate measure names")
        rollups = tuple(tuple(r) for r in self.rollups) or (tuple(names),)
        for r in rollups:
            unknown = set(r) - set(names)
            if unknown:
                raise ValueError(f"cube {self.name}: rollup over unknown dims {unknown}")
        if tuple(names) not in rollups:
            rollups = (tuple(names),) + rollups
        object.__setattr__(self, "rollups", rollups)
        if self.method not in ("auto", "onehot", "dense", "kernel"):
            raise ValueError(f"cube {self.name}: unknown method {self.method!r}")

    # -- derived geometry ---------------------------------------------------
    def dim(self, name: str) -> Dimension:
        for d in self.dimensions:
            if d.name == name:
                return d
        raise KeyError(name)

    @property
    def dim_names(self) -> tuple:
        return tuple(d.name for d in self.dimensions)

    @property
    def measure_names(self) -> tuple:
        return tuple(m.name for m in self.measures)

    @property
    def shape(self) -> tuple:
        """Cell grid of the finest rollup, one axis per dimension."""
        return tuple(d.cardinality for d in self.dimensions)

    @property
    def num_cells(self) -> int:
        return math.prod(self.shape)

    def rollup_shape(self, rollup: Sequence[str]) -> tuple:
        return tuple(self.dim(n).cardinality for n in rollup)

    def rollup_cells(self, rollup: Sequence[str]) -> int:
        return math.prod(self.rollup_shape(rollup))

    def resolve_method(self) -> str:
        if self.method != "auto":
            return self.method
        return "onehot" if self.num_cells <= self.ONEHOT_MAX_GROUPS else "dense"

    def covering_rollups(self, needed_dims) -> list:
        """Rollups containing every dim in ``needed_dims``, coarsest (fewest
        cells) first — the router picks the cheapest covering slice."""
        needed = set(needed_dims)
        out = [r for r in self.rollups if needed <= set(r)]
        return sorted(out, key=self.rollup_cells)
