"""Tier-1 query router: answer aggregate queries from rollup cubes.

The router matches the declarative Query IR directly: a ``GroupAgg`` root
over ``Filter``/``Project`` chains on a scan is DERIVED into the internal
``AggQuery`` form per cube spec (group keys -> dimensions by column/edges,
measures -> spec measures by structural expression equality, filter
conjuncts -> dimension predicates), then the cheapest covering rollup —
contains every grouped/filtered dimension, can express every filter
exactly, has every measure — answers it by masking + marginalizing the
dense rollup array on the host (microseconds; no device round-trip).
Queries that derive or route to nothing return ``None`` and the caller
falls back to Tier 2, the compiled SPMD plan over the base tables
(``TPCHDriver.query``).

Exactness rule for binned dimensions: bin ``j`` covers ``(edges[j-1],
edges[j]]``, so a range predicate is answerable iff its bound lands on an
edge (``<= v`` with ``v`` an edge; ``> v`` likewise; integer domains also
get ``< v`` / ``>= v`` via the ``v - 1`` edge).  Anything else is routed to
Tier 2 rather than answered approximately.

PARAMETERIZED predicates (``col op Param``, the prepared-statement form)
split that decision across time: at prepare/route time only the SHAPE is
checked (the filtered column must be a dimension of a covering rollup —
value exactness cannot be known yet), and at execute time
:meth:`CubeRouter.answer_bound` substitutes the binding and applies the
edge-exactness rule per call — an in-range binding on an edge serves Tier
1, anything else returns None and the caller falls back to the prepared
Tier-2 plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.cube.build import ROWS, Cube
from repro.cube.spec import Dimension
from repro.query import ir as qir


@dataclasses.dataclass(frozen=True)
class Filter:
    """Predicate on one cube dimension.  For categorical dims ``value`` is a
    dictionary code (or tuple of codes for op "in"); for binned dims it is a
    raw column value tested against the bin edges.  A
    :class:`~repro.query.ir.Param` value is a placeholder resolved at
    execute time (:meth:`CubeRouter.answer_bound`)."""

    dim: str
    op: str  # ==, in, <=, <, >=, >
    value: object

    @property
    def parameterized(self) -> bool:
        return isinstance(self.value, qir.Param)


@dataclasses.dataclass(frozen=True)
class AggQuery:
    """The router's internal matched form: an aggregate query named in one
    spec's dimension/measure vocabulary.  Derived from a ``GroupAgg`` IR
    root by :func:`derive_agg_query`; can also be built directly in tests.

    group_by: dimension names, in output-axis order.
    measures: measure names, stacked on the last output axis.
    filters: conjunctive predicates on cube dimensions.
    """

    table: str
    group_by: tuple
    measures: tuple
    filters: tuple = ()


@dataclasses.dataclass(frozen=True)
class Route:
    cube: Cube
    rollup: tuple  # ordered dim names of the chosen rollup

    @property
    def cells(self) -> int:
        return self.cube.spec.rollup_cells(self.rollup)


@dataclasses.dataclass(frozen=True)
class Match:
    """A successful IR->cube match: the derived AggQuery plus its route."""

    query: AggQuery
    route: Route


# ---------------------------------------------------------------------------
# IR -> AggQuery derivation (per spec)
# ---------------------------------------------------------------------------


def _measure_expr(m) -> Optional[qir.Expr]:
    """Spec measure as an IR expression, or None if unmatchable (legacy
    callable measures)."""
    if isinstance(m.column, qir.Expr):
        return m.column
    if isinstance(m.column, str):
        return qir.Col(m.column)
    return None


def _dim_for_key(spec, key: qir.GroupKey) -> Optional[Dimension]:
    """Cube dimension matching a group key: plain ``Col`` -> categorical
    dim of that column with the same cardinality; ``Bin`` -> binned dim of
    that column with identical edges."""
    e = key.expr
    if isinstance(e, qir.Col):
        for d in spec.dimensions:
            if d.column == e.name and not d.binned \
                    and d.cardinality == key.cardinality:
                return d
    elif isinstance(e, qir.Bin) and isinstance(e.child, qir.Col):
        for d in spec.dimensions:
            if d.column == e.child.name and d.binned \
                    and d.edges == e.edges:
                return d
    return None


def _dim_for_column(spec, column: str) -> Optional[Dimension]:
    for d in spec.dimensions:
        if d.column == column:
            return d
    return None


def derive_agg_query(q: "qir.Query", spec) -> Optional[AggQuery]:
    """Express an IR query in ``spec``'s vocabulary, or None when the query
    is not cube-shaped for this spec (non-GroupAgg root, operators a cube
    cannot represent, unknown measures/dimensions)."""
    root = q.root
    if not isinstance(root, qir.GroupAgg):
        return None
    # walk the chain below the GroupAgg: only Filter/Project over a Scan of
    # the spec's table are representable; projections are inlined
    chain = []
    node = root.child
    while not isinstance(node, qir.Scan):
        if not isinstance(node, (qir.Filter, qir.Project)):
            return None  # SemiJoin/Exists/GroupAggByKey: not cube-shaped
        chain.append(node)
        node = node.child
    if node.table != spec.table:
        return None
    # resolve scan-upward so each binding/predicate sees only the
    # projections BELOW it; stored env entries are fully base-column
    # expressions, and an upper projection may shadow a lower one
    filters = []
    env = {}
    for op in reversed(chain):
        if isinstance(op, qir.Filter):
            filters.append(qir.substitute(op.pred, env) if env else op.pred)
        else:
            for name, e in op.cols:
                env[name] = qir.substitute(e, env) if env else e

    def subst(e):
        return qir.substitute(e, env) if env else e

    group_by = []
    for key in root.keys:
        d = _dim_for_key(spec, dataclasses.replace(key, expr=subst(key.expr))
                         if env else key)
        if d is None:
            return None
        group_by.append(d.name)

    measures = []
    for agg in root.aggs:
        found = None
        for m in spec.measures:
            if m.agg != agg.agg:
                continue
            if agg.agg == "count" or qir.same_expr(_measure_expr(m),
                                                   subst(agg.expr)):
                found = m.name
                break
        if found is None:
            return None
        measures.append(found)

    dim_filters = []
    for pred in filters:  # already substituted at collection position
        for factor in qir.conjuncts(pred):
            norm = qir.normalize_comparison(factor)
            if norm is None:
                return None
            column, op, value = norm
            d = _dim_for_column(spec, column)
            if d is None:
                return None
            dim_filters.append(Filter(d.name, op, value))

    return AggQuery(
        table=spec.table,
        group_by=tuple(group_by),
        measures=tuple(measures),
        filters=tuple(dim_filters),
    )


# ---------------------------------------------------------------------------
# filter masks over a dimension's code space
# ---------------------------------------------------------------------------


def _is_int(v) -> bool:
    return isinstance(v, (int, np.integer))


def _filter_mask(dim, flt: Filter):
    """Boolean mask over ``dim``'s code space, or None if the predicate is
    not exactly expressible on this dimension's granularity."""
    card = dim.cardinality
    codes = np.arange(card)
    if not dim.binned:
        v = flt.value
        if flt.op == "==":
            return codes == v
        if flt.op == "in":
            return np.isin(codes, np.asarray(list(v)))
        if flt.op == "<=":
            return codes <= v
        if flt.op == "<":
            return codes < v
        if flt.op == ">=":
            return codes >= v
        if flt.op == ">":
            return codes > v
        return None
    # binned: translate the raw bound to an edge index.  Strict bounds are
    # rewritten through v-1 only on declared-integer domains (on floats,
    # '< 10' != '<= 9') — otherwise they are inexact and go to Tier 2.
    edges = np.asarray(dim.edges)
    op, v = flt.op, flt.value
    if op == "<" and dim.integral and _is_int(v):
        op, v = "<=", v - 1
    if op == ">=" and dim.integral and _is_int(v):
        op, v = ">", v - 1
    j = np.searchsorted(edges, v)
    if j >= len(edges) or edges[j] != v:
        # the bound cuts INSIDE a bin (including the open first/last bins,
        # which extend beyond the edge list) — not exact, Tier 2
        return None
    if op == "<=":
        return codes <= j
    if op == ">":
        return codes > j
    return None


class CubeRouter:
    """Match queries (IR or derived AggQuery form) against built cubes.

    With an :class:`repro.obs.Observer` attached (``obs``), routing
    decisions feed the metrics registry: ``router.match`` / ``router.miss``
    count tier-1 coverage at route time, and ``router.offedge_fallback``
    counts bound executions a matched route had to hand back to Tier 2
    because the binding was not exactly expressible on the cube's bin
    edges."""

    def __init__(self, cubes: Sequence[Cube], obs=None):
        self.cubes = list(cubes)
        self.obs = obs

    def _count(self, name: str):
        if self.obs is not None and self.obs.metrics is not None:
            self.obs.metrics.counter(name).inc()

    def add(self, cube: Cube):
        self.cubes.append(cube)

    # -- matching -----------------------------------------------------------
    def _match_cube(self, cube: Cube, q: AggQuery) -> Optional[Route]:
        """Cheapest covering rollup of ONE cube, or None."""
        spec = cube.spec
        if spec.table != q.table:
            return None
        if not set(q.measures) <= set(spec.measure_names):
            return None
        needed = set(q.group_by) | {f.dim for f in q.filters}
        if not needed <= set(spec.dim_names):
            return None
        # value exactness of parameterized filters is unknowable until a
        # binding arrives — answer_bound() re-checks it per execution
        if any(_filter_mask(spec.dim(f.dim), f) is None
               for f in q.filters if not f.parameterized):
            return None
        for rollup in spec.covering_rollups(needed):
            ordered = tuple(n for n in spec.dim_names if n in rollup)
            if ordered in cube.rollups:
                return Route(cube, ordered)  # sorted; first is cheapest
        return None

    def route(self, q: AggQuery) -> Optional[Route]:
        """Cheapest covering (cube, rollup) for a pre-derived AggQuery."""
        best = None
        for cube in self.cubes:
            route = self._match_cube(cube, q)
            if route is not None and (best is None or route.cells < best.cells):
                best = route
        return best

    def route_query(self, q: "qir.Query") -> Optional[Match]:
        """Match an IR query: derive the AggQuery per spec (dimension and
        measure vocabularies differ between cubes), keep the cheapest
        covering route.  None -> Tier 2."""
        best = None
        for cube in self.cubes:
            aggq = derive_agg_query(q, cube.spec)
            if aggq is None:
                continue
            route = self._match_cube(cube, aggq)
            if route is not None and (
                    best is None or route.cells < best.route.cells):
                best = Match(query=aggq, route=route)
        self._count("router.match" if best is not None else "router.miss")
        if best is not None and self.obs is not None:
            self.obs.event(
                "router.route", cat="route", query=q.name or "<anon>",
                cube=best.route.cube.spec.name,
                rollup="x".join(best.route.rollup),
                cells=best.route.cells,
            )
        return best

    # -- answering ----------------------------------------------------------
    def answer_bound(self, match: Match, binding=None):
        """Execute-time Tier-1 answer for a (possibly parameterized) match:
        substitute ``binding`` into the parameterized filters, THEN apply
        the bin-edge exactness rule per filter.  Returns the dense result,
        or None when any bound value is not exactly expressible on its
        dimension (off-edge or out-of-range binding) — the caller falls
        back to the prepared Tier-2 plan."""
        q, spec = match.query, match.route.cube.spec
        resolved = []
        for f in q.filters:
            if f.parameterized:
                if binding is None or f.value.name not in binding:
                    raise qir.UnboundParamError(
                        f"cube filter on {f.dim!r} needs a binding for "
                        f"parameter {f.value.name!r}"
                    )
                v = binding[f.value.name]
                f = dataclasses.replace(
                    f, value=v.item() if hasattr(v, "item") else v)
            resolved.append(f)
        if any(_filter_mask(spec.dim(f.dim), f) is None for f in resolved):
            self._count("router.offedge_fallback")
            return None
        return self.answer(dataclasses.replace(q, filters=tuple(resolved)),
                           match.route)

    def answer(self, q: AggQuery, route: Optional[Route] = None):
        """Dense result of shape ``(*group_by cardinalities, len(measures))``
        (float64), or None when no cube covers the query.  Empty min/max
        cells come back NaN."""
        if any(f.parameterized for f in q.filters):
            raise qir.UnboundParamError(
                "answer() needs concrete filter values — resolve "
                "parameterized filters via answer_bound(match, binding)"
            )
        route = route or self.route(q)
        if route is None:
            return None
        spec = route.cube.spec
        arrays = route.cube.rollup(route.rollup)
        dims = route.rollup

        rows = arrays[ROWS].astype(np.float64)
        # conjunction of all predicates per dimension (a query may carry
        # several filters on one dim, e.g. a date window)
        masks = {}
        for f in q.filters:
            m = _filter_mask(spec.dim(f.dim), f)
            masks[f.dim] = m if f.dim not in masks else masks[f.dim] & m

        def _shaped(mask, axis):
            shape = [1] * len(dims)
            shape[axis] = mask.shape[0]
            return mask.reshape(shape)

        reduce_axes = tuple(i for i, d in enumerate(dims) if d not in q.group_by)
        rows_f = rows
        for dname, mask in masks.items():
            rows_f = rows_f * _shaped(mask, dims.index(dname))
        rows_out = rows_f.sum(axis=reduce_axes) if reduce_axes else rows_f

        outs = []
        for mname in q.measures:
            agg = next(m.agg for m in spec.measures if m.name == mname)
            arr = arrays[mname].astype(np.float64)
            for dname, mask in masks.items():
                m = _shaped(mask, dims.index(dname))
                if agg in ("sum", "count"):
                    arr = arr * m
                else:
                    fill = np.inf if agg == "min" else -np.inf
                    arr = np.where(m, arr, fill)
            if reduce_axes:
                if agg in ("sum", "count"):
                    arr = arr.sum(axis=reduce_axes)
                elif agg == "min":
                    arr = arr.min(axis=reduce_axes)
                else:
                    arr = arr.max(axis=reduce_axes)
            if agg in ("min", "max"):
                arr = np.where(rows_out > 0, arr, np.nan)
            outs.append(arr)

        kept = [d for d in dims if d in q.group_by]
        stacked = np.stack(outs, axis=-1)
        # reorder the surviving dim axes to the query's group_by order
        perm = [kept.index(g) for g in q.group_by]
        return np.transpose(stacked, perm + [len(kept)])
