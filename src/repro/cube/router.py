"""Tier-1 query router: answer aggregate queries from rollup cubes.

An ``AggQuery`` describes an aggregate query abstractly (group-by dims,
filters on cube dims, measures).  The router finds the cheapest rollup that
*covers* the query — contains every grouped/filtered dimension, can express
every filter exactly, and has every requested measure — then answers it by
masking + marginalizing the dense rollup array on the host (microseconds;
no device round-trip).  Queries with no covering rollup return ``None`` and
the caller falls back to Tier 2, the precompiled SPMD plan over the base
tables (``TPCHDriver.query``).

Exactness rule for binned dimensions: bin ``j`` covers ``(edges[j-1],
edges[j]]``, so a range predicate is answerable iff its bound lands on an
edge (``<= v`` with ``v`` an edge; ``> v`` likewise; integer domains also
get ``< v`` / ``>= v`` via the ``v - 1`` edge).  Anything else is routed to
Tier 2 rather than answered approximately.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.cube.build import ROWS, Cube


@dataclasses.dataclass(frozen=True)
class Filter:
    """Predicate on one cube dimension.  For categorical dims ``value`` is a
    dictionary code (or tuple of codes for op "in"); for binned dims it is a
    raw column value tested against the bin edges."""

    dim: str
    op: str  # ==, in, <=, <, >=, >
    value: object


@dataclasses.dataclass(frozen=True)
class AggQuery:
    """Abstract aggregate query over one table.

    group_by: dimension names, in output-axis order.
    measures: measure names, stacked on the last output axis.
    filters: conjunctive predicates on cube dimensions.
    fallback: Tier-2 plan name (``core.plans.PLANS`` key) to run when no
        cube covers the query.
    """

    table: str
    group_by: tuple
    measures: tuple
    filters: tuple = ()
    fallback: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Route:
    cube: Cube
    rollup: tuple  # ordered dim names of the chosen rollup

    @property
    def cells(self) -> int:
        return self.cube.spec.rollup_cells(self.rollup)


def _is_int(v) -> bool:
    return isinstance(v, (int, np.integer))


def _filter_mask(dim, flt: Filter):
    """Boolean mask over ``dim``'s code space, or None if the predicate is
    not exactly expressible on this dimension's granularity."""
    card = dim.cardinality
    codes = np.arange(card)
    if not dim.binned:
        v = flt.value
        if flt.op == "==":
            return codes == v
        if flt.op == "in":
            return np.isin(codes, np.asarray(list(v)))
        if flt.op == "<=":
            return codes <= v
        if flt.op == "<":
            return codes < v
        if flt.op == ">=":
            return codes >= v
        if flt.op == ">":
            return codes > v
        return None
    # binned: translate the raw bound to an edge index.  Strict bounds are
    # rewritten through v-1 only on declared-integer domains (on floats,
    # '< 10' != '<= 9') — otherwise they are inexact and go to Tier 2.
    edges = np.asarray(dim.edges)
    op, v = flt.op, flt.value
    if op == "<" and dim.integral and _is_int(v):
        op, v = "<=", v - 1
    if op == ">=" and dim.integral and _is_int(v):
        op, v = ">", v - 1
    j = np.searchsorted(edges, v)
    if j >= len(edges) or edges[j] != v:
        # the bound cuts INSIDE a bin (including the open first/last bins,
        # which extend beyond the edge list) — not exact, Tier 2
        return None
    if op == "<=":
        return codes <= j
    if op == ">":
        return codes > j
    return None


class CubeRouter:
    """Match aggregate queries against a set of built cubes."""

    def __init__(self, cubes: Sequence[Cube]):
        self.cubes = list(cubes)

    def add(self, cube: Cube):
        self.cubes.append(cube)

    # -- matching -----------------------------------------------------------
    def route(self, q: AggQuery) -> Optional[Route]:
        """Cheapest covering (cube, rollup), or None → Tier 2."""
        needed = set(q.group_by) | {f.dim for f in q.filters}
        best = None
        for cube in self.cubes:
            spec = cube.spec
            if spec.table != q.table:
                continue
            if not set(q.measures) <= set(spec.measure_names):
                continue
            if not needed <= set(spec.dim_names):
                continue
            if any(_filter_mask(spec.dim(f.dim), f) is None for f in q.filters):
                continue
            for rollup in spec.covering_rollups(needed):
                ordered = tuple(n for n in spec.dim_names if n in rollup)
                if ordered in cube.rollups:
                    route = Route(cube, ordered)
                    if best is None or route.cells < best.cells:
                        best = route
                    break  # covering_rollups is sorted; first is cheapest
        return best

    # -- answering ----------------------------------------------------------
    def answer(self, q: AggQuery, route: Optional[Route] = None):
        """Dense result of shape ``(*group_by cardinalities, len(measures))``
        (float64), or None when no cube covers the query.  Empty min/max
        cells come back NaN."""
        route = route or self.route(q)
        if route is None:
            return None
        spec = route.cube.spec
        arrays = route.cube.rollup(route.rollup)
        dims = route.rollup

        rows = arrays[ROWS].astype(np.float64)
        # conjunction of all predicates per dimension (a query may carry
        # several filters on one dim, e.g. a date window)
        masks = {}
        for f in q.filters:
            m = _filter_mask(spec.dim(f.dim), f)
            masks[f.dim] = m if f.dim not in masks else masks[f.dim] & m

        def _shaped(mask, axis):
            shape = [1] * len(dims)
            shape[axis] = mask.shape[0]
            return mask.reshape(shape)

        reduce_axes = tuple(i for i, d in enumerate(dims) if d not in q.group_by)
        rows_f = rows
        for dname, mask in masks.items():
            rows_f = rows_f * _shaped(mask, dims.index(dname))
        rows_out = rows_f.sum(axis=reduce_axes) if reduce_axes else rows_f

        outs = []
        for mname in q.measures:
            agg = next(m.agg for m in spec.measures if m.name == mname)
            arr = arrays[mname].astype(np.float64)
            for dname, mask in masks.items():
                m = _shaped(mask, dims.index(dname))
                if agg in ("sum", "count"):
                    arr = arr * m
                else:
                    fill = np.inf if agg == "min" else -np.inf
                    arr = np.where(m, arr, fill)
            if reduce_axes:
                if agg in ("sum", "count"):
                    arr = arr.sum(axis=reduce_axes)
                elif agg == "min":
                    arr = arr.min(axis=reduce_axes)
                else:
                    arr = arr.max(axis=reduce_axes)
            if agg in ("min", "max"):
                arr = np.where(rows_out > 0, arr, np.nan)
            outs.append(arr)

        kept = [d for d in dims if d in q.group_by]
        stacked = np.stack(outs, axis=-1)
        # reorder the surviving dim axes to the query's group_by order
        perm = [kept.index(g) for g in q.group_by]
        return np.transpose(stacked, perm + [len(kept)])
