"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend STUB + gemma decoder, bidirectional image
prefix [arXiv:2407.07726; hf]."""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256,
    norm="rmsnorm", act="geglu", tie_embeddings=True,
    vlm=VLMConfig(num_patches=256, patch_dim=1152),
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=96, vocab_size=256, head_dim=16,
    norm="rmsnorm", act="geglu", tie_embeddings=True,
    vlm=VLMConfig(num_patches=8, patch_dim=24),
    compute_dtype="float32",
)
