from repro.configs.registry import ARCHS, SHAPES, get_arch, runnable_cells  # noqa: F401
