"""whisper-medium [audio]: 24+24L d_model=1024 16H d_ff=4096 vocab=51865 —
enc-dec; conv/mel frontend is a STUB (input_specs provides precomputed frame
embeddings) [arXiv:2212.04356]."""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    norm="layernorm", act="gelu", tie_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=24, enc_seq=1500),
    max_seq=32768,
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=256,
    norm="layernorm", act="gelu", tie_embeddings=True,
    encdec=EncDecConfig(n_enc_layers=2, enc_seq=32),
    max_seq=128, compute_dtype="float32",
)
