"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, ssm_state=128 — SSD
(state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_width=4, chunk=256),
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=256, tie_embeddings=True,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4, chunk=16),
    sub_quadratic=True, compute_dtype="float32",
)
