"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680 —
RG-LRU + local attention in a (rec, rec, attn) pattern, window 2048
[arXiv:2402.19427; hf]."""
from repro.models.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    norm="rmsnorm", act="geglu", tie_embeddings=True,
    attn_window=2048,
    hybrid=HybridConfig(lru_width=2560, period=3, attn_position=2, window=2048),
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=96, vocab_size=256, head_dim=16,
    norm="rmsnorm", act="geglu", tie_embeddings=True,
    attn_window=16,
    hybrid=HybridConfig(lru_width=64, period=3, attn_position=2, window=16),
    sub_quadratic=True, compute_dtype="float32",
)
