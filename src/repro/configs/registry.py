"""Architecture + input-shape registry: the 10 assigned archs x 4 shapes
(40 cells), with per-cell runnability rules from the brief:

- ``decode_*``/``long_*`` lower the SERVE step (one token + cache), not train.
- ``long_500k`` requires sub-quadratic decode state -> runs only for
  mamba2-2.7b (SSD) and recurrentgemma-2b (RG-LRU + bounded window); the 8
  pure full-attention archs skip it (recorded, see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

_MODULES = {
    "yi-34b": "repro.configs.yi_34b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "whisper-medium": "repro.configs.whisper_medium",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe_42b_a6_6b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ARCHS = tuple(_MODULES)


def get_arch(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.CONFIG


def cell_runnable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention KV state at 524288 tokens is not "
                       "sub-quadratic; skipped per brief (DESIGN.md §5)")
    return True, ""


def runnable_cells():
    """All (arch, shape) pairs with runnability verdicts — 40 cells."""
    out = []
    for a in ARCHS:
        cfg = get_arch(a)
        for s in SHAPES.values():
            ok, why = cell_runnable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
