"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — partial ('2d') RoPE over half the head dim, GQA
[arXiv:2406.12793; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    norm="rmsnorm", act="swiglu", rope_fraction=0.5, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=256,
    norm="rmsnorm", act="swiglu", rope_fraction=0.5, qkv_bias=True,
    compute_dtype="float32",
)
