"""Continuous-batching OLAP serving engine: the concurrent-load tier.

Every benchmark before this module measured ONE synchronous client; the
paper's whole point (and the ROADMAP's north star) is maximal hardware
utilization under concurrent analytical load.  This engine accepts an
async stream of query submissions and drives the pieces the repo already
has — microsecond Tier-1 cube answers, compile-once prepared plans, and
vmap-batched parameter execution — under a continuous-batching policy
borrowed from LLM serving (Yu et al.'s ORCA idea, applied to prepared
OLAP plans):

- **Tier-1 first, inline, never queued.**  ``submit`` probes the cube
  router synchronously on the event loop (``PreparedQuery.answer_tier1``
  is pure host-side numpy); a covered, on-edge binding is answered in
  microseconds without ever entering a queue, so interactive dashboard
  traffic cannot sit behind a Tier-2 scan.

- **Shape-keyed admission queues.**  Everything else is admitted to a
  per-shape queue (``PreparedQuery.shape_key`` — same key means the
  bindings stack into one executable).  Admission is bounded
  (``max_queue``): past the bound, ``submit`` raises
  :class:`AdmissionError` instead of growing latency without limit.

- **Dynamic batches.**  A per-shape dispatcher seals a batch when the
  queue reaches ``max_batch`` OR the oldest request has waited
  ``max_wait_us``, whichever comes first, and dispatches it through
  ``execute_batch`` as ONE vmapped SPMD device call.  Late arrivals join
  the NEXT batch rather than blocking the sealed one — the pipeline
  stays full under sustained load (Rödiger et al.'s
  keep-the-network-busy argument, applied to the dispatch path).
  Batches are padded to power-of-two lane counts so the jitted batched
  executable specializes O(log max_batch) times, not once per observed
  size; a batch of one takes the scalar executable (no vmap trace).

- **Bounded dispatch pipelining.**  At most ``max_inflight`` Tier-2
  dispatches are in flight at once, each on a thread-pool worker — the
  blocking ``jax`` call releases the GIL while XLA computes, so the
  event loop keeps admitting and answering Tier-1 during Tier-2 flight.
  The actual DEVICE executions serialize at the driver's dispatch gate
  (two XLA host-platform collective programs deadlock if they rendezvous
  concurrently — see ``TPCHDriver._guarded_call``); what overlaps across
  workers is the host side: binding casts, parameter stacking, and
  ``device_get`` of the previous answer while the next batch computes.

Observability: per-request detached spans (``serve.request``), a
``serve.queue_depth`` gauge, ``serve.batch_size`` / ``serve.queue_us`` /
``serve.tier1_us`` / ``serve.e2e_us`` histograms and ``serve.*``
counters, all in the driver's metrics registry (thread-safe as of this
PR).

Usage::

    engine = OLAPEngine(driver, max_batch=16, max_wait_us=2000)
    async with engine:
        ans = await engine.submit(query_or_prepared, params)
"""
from __future__ import annotations

import asyncio
import sys
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.tpch.driver import PreparedQuery, QueryAnswer


class AdmissionError(RuntimeError):
    """The engine refused a submission (queue bound exceeded, or the
    engine is not running)."""


class _Pending:
    """One queued Tier-2 request: its full binding, the future its client
    awaits, and the enqueue timestamp the batching window runs on."""

    __slots__ = ("binding", "future", "t_enq")

    def __init__(self, binding, future, t_enq):
        self.binding = binding
        self.future = future
        self.t_enq = t_enq


class _ShapeLane:
    """Per-shape queue + wakeup event; one dispatcher task drains it."""

    __slots__ = ("prep", "pending", "event", "task")

    def __init__(self, prep: PreparedQuery):
        self.prep = prep            # canonical handle for this shape
        self.pending: deque = deque()
        self.event: asyncio.Event = asyncio.Event()
        self.task: Optional[asyncio.Task] = None


def _lane_view(value, i: int):
    """Lane ``i`` of a batched answer value (array or dict-of-arrays —
    every output of ``execute_batch`` carries a leading lane axis)."""
    if isinstance(value, dict):
        return {k: np.asarray(v)[i] for k, v in value.items()}
    return np.asarray(value)[i]


def _bucket(n: int, cap: int) -> int:
    """Next power of two >= n, capped at ``cap`` — the fixed lane counts
    batches are padded to."""
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


class OLAPEngine:
    """Async serving loop over one :class:`~repro.tpch.driver.TPCHDriver`.

    Construct, then ``async with engine:`` (or ``await engine.start()`` /
    ``await engine.stop()``).  ``submit`` may be called from any task on
    the engine's event loop; the underlying driver is thread-safe, so a
    separate synchronous client hitting the same driver concurrently is
    also supported.
    """

    def __init__(self, driver, *, max_batch: int = 16,
                 max_wait_us: float = 2000.0, max_queue: int = 4096,
                 max_inflight: int = 2, pad_batches: bool = True):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.driver = driver
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_us) * 1e-6
        self.max_queue = int(max_queue)
        self.max_inflight = int(max_inflight)
        self.pad_batches = bool(pad_batches)
        self.obs = driver.obs
        self._lanes: dict = {}      # shape_key -> _ShapeLane
        self._depth = 0             # queued Tier-2 requests, all lanes
        self._active = 0            # Tier-2 dispatches in flight
        self._running = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._sem: Optional[asyncio.Semaphore] = None
        self._pool: Optional[ThreadPoolExecutor] = None

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> "OLAPEngine":
        if self._running:
            return self
        self._loop = asyncio.get_running_loop()
        self._sem = asyncio.Semaphore(self.max_inflight)
        self._pool = ThreadPoolExecutor(
            max_workers=self.max_inflight + 1,
            thread_name_prefix="olap-serve")
        # the Tier-1 inline path is ~100us of numpy on the event loop; at
        # the interpreter's default 5ms GIL switch interval one busy
        # executor thread (host-side batch stacking) may hold the GIL for
        # ~50x the whole path — bound the worst-case hold while serving,
        # restore on stop
        self._switch_interval = sys.getswitchinterval()
        sys.setswitchinterval(2e-4)
        self._running = True
        return self

    async def stop(self, drain: bool = True) -> None:
        """Stop the engine.  ``drain=True`` (default) first waits for every
        queued request and in-flight batch to complete; ``drain=False``
        fails queued requests with :class:`AdmissionError`."""
        if not self._running:
            return
        if drain:
            while self._depth or self._active:
                await asyncio.sleep(0.0005)
        self._running = False
        for lane in self._lanes.values():
            if lane.task is not None:
                lane.task.cancel()
            lane.event.set()
        for lane in self._lanes.values():
            if lane.task is not None:
                try:
                    await lane.task
                except asyncio.CancelledError:
                    pass
                lane.task = None
            while lane.pending:
                p = lane.pending.popleft()
                self._depth -= 1
                if not p.future.done():
                    p.future.set_exception(
                        AdmissionError("engine stopped with request queued"))
        self._gauge_depth()
        self._pool.shutdown(wait=True)
        self._pool = None
        sys.setswitchinterval(self._switch_interval)

    async def __aenter__(self) -> "OLAPEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop(drain=exc == (None, None, None))

    # -- submission ---------------------------------------------------------
    def prepare(self, q) -> PreparedQuery:
        """Prepare once, submit many: the returned handle skips per-submit
        canonicalization and is the coalescing key."""
        return self.driver.prepare(q)

    async def submit(self, q, params: Optional[dict] = None) -> QueryAnswer:
        """Serve one query: a :class:`~repro.query.Query` (prepared here)
        or a :class:`PreparedQuery` handle, plus an optional binding.

        Cube-covered on-edge bindings return synchronously (Tier 1);
        everything else resolves when its (possibly coalesced) Tier-2
        dispatch lands.  Raises :class:`AdmissionError` when the engine
        is stopped or the shape's queue is at ``max_queue``.
        """
        if not self._running:
            raise AdmissionError("engine is not running (use 'async with')")
        mreg = self.obs.metrics
        mreg.counter("serve.requests").inc()
        t0 = time.perf_counter()
        prep = q if isinstance(q, PreparedQuery) else self.driver.prepare(q)
        if not isinstance(prep, PreparedQuery):  # pragma: no cover
            raise TypeError(f"submit() takes a Query or PreparedQuery, "
                            f"got {type(q)}")
        sp = self.obs.open_span("serve.request", cat="serve",
                                source=prep.source)
        try:
            b = prep.binding(params)
            ans = prep.answer_tier1(b)
            if ans is not None:
                dt_us = (time.perf_counter() - t0) * 1e6
                mreg.counter("serve.tier1").inc()
                mreg.histogram("serve.tier1_us").record(dt_us)
                sp.set(tier=1, route=ans.source)
                return ans
            if not prep.params:
                # literal shape: nothing to stack on — dispatch solo
                ans = await self._run_solo(prep, sp)
            else:
                ans = await self._enqueue(prep, b, t0, sp)
            mreg.histogram("serve.e2e_us").record(
                (time.perf_counter() - t0) * 1e6)
            return ans
        except BaseException:
            sp.set(error=True)
            raise
        finally:
            self.obs.close_span(sp)

    # -- internals ----------------------------------------------------------
    def _gauge_depth(self) -> None:
        self.obs.metrics.gauge("serve.queue_depth").set(self._depth)

    async def _run_solo(self, prep: PreparedQuery, sp) -> QueryAnswer:
        self.obs.metrics.counter("serve.solo").inc()
        await self._sem.acquire()
        self._active += 1
        try:
            ans = await self._loop.run_in_executor(self._pool, prep.execute)
        finally:
            self._active -= 1
            self._sem.release()
        sp.set(tier=ans.tier, route=ans.source)
        return ans

    async def _enqueue(self, prep: PreparedQuery, binding: dict,
                       t0: float, sp) -> QueryAnswer:
        if self._depth >= self.max_queue:
            self.obs.metrics.counter("serve.rejected").inc()
            raise AdmissionError(
                f"admission queue full ({self._depth} >= {self.max_queue})")
        lane = self._lanes.get(prep.shape_key)
        if lane is None:
            lane = self._lanes[prep.shape_key] = _ShapeLane(prep)
            lane.task = self._loop.create_task(self._dispatch_loop(lane))
        p = _Pending(binding, self._loop.create_future(), t0)
        lane.pending.append(p)
        self._depth += 1
        self._gauge_depth()
        lane.event.set()
        ans = await p.future
        sp.set(tier=ans.tier, route=ans.source,
               queue_us=(p.t_enq and (time.perf_counter() - p.t_enq) * 1e6))
        return ans

    async def _dispatch_loop(self, lane: _ShapeLane) -> None:
        """One shape's continuous-batching loop: wait for work, hold the
        batching window open until ``max_batch`` or ``max_wait_us``, seal,
        dispatch without awaiting (late arrivals accumulate for the next
        batch while this one flies)."""
        while self._running:
            if not lane.pending:
                lane.event.clear()
                await lane.event.wait()
                continue
            deadline = lane.pending[0].t_enq + self.max_wait_s
            while len(lane.pending) < self.max_batch:
                delay = deadline - time.perf_counter()
                if delay <= 0:
                    break
                lane.event.clear()
                try:
                    await asyncio.wait_for(lane.event.wait(), delay)
                except asyncio.TimeoutError:
                    break
            n = min(len(lane.pending), self.max_batch)
            batch = [lane.pending.popleft() for _ in range(n)]
            await self._sem.acquire()  # bounds device concurrency
            self._active += 1
            self._depth -= n
            self._gauge_depth()
            # fire-and-continue: the loop seals the next batch while this
            # one executes (the semaphore is released by _run_batch)
            self._loop.create_task(self._run_batch(lane, batch))

    async def _run_batch(self, lane: _ShapeLane, batch: list) -> None:
        mreg = self.obs.metrics
        try:
            t_disp = time.perf_counter()
            for p in batch:
                mreg.histogram("serve.queue_us").record(
                    (t_disp - p.t_enq) * 1e6)
            mreg.histogram("serve.batch_size").record(len(batch))
            mreg.counter("serve.batches").inc()
            prep, rows = lane.prep, [p.binding for p in batch]
            pad = (_bucket(len(rows), self.max_batch)
                   if self.pad_batches else None)

            def work():
                if len(rows) == 1:
                    return prep.execute(rows[0])
                return prep.execute_batch(rows, pad_to=pad)

            try:
                ans = await self._loop.run_in_executor(self._pool, work)
            except BaseException as e:
                for p in batch:
                    if not p.future.done():
                        p.future.set_exception(e)
                return
            if len(batch) == 1:
                if not batch[0].future.done():
                    batch[0].future.set_result(ans)
                return
            mreg.counter("serve.coalesced_lanes").inc(len(batch))
            overflow = np.asarray(ans.overflow)
            for i, p in enumerate(batch):
                if p.future.done():
                    continue
                p.future.set_result(QueryAnswer(
                    _lane_view(ans.value, i), tier=ans.tier,
                    source=ans.source, overflow=bool(overflow[i])))
        finally:
            self._active -= 1
            self._sem.release()

    # -- introspection ------------------------------------------------------
    def stats(self) -> dict:
        """Live snapshot of the serving metrics (plain data)."""
        mreg = self.obs.metrics
        out = {
            "requests": mreg.value("serve.requests"),
            "tier1": mreg.value("serve.tier1"),
            "solo": mreg.value("serve.solo"),
            "batches": mreg.value("serve.batches"),
            "coalesced_lanes": mreg.value("serve.coalesced_lanes"),
            "rejected": mreg.value("serve.rejected"),
            "queue_depth": self._depth,
            "lanes": len(self._lanes),
        }
        for h in ("serve.batch_size", "serve.queue_us", "serve.tier1_us",
                  "serve.e2e_us"):
            m = mreg.get(h)
            if m is not None and m.count:
                out[h] = m.snapshot()
        return out
