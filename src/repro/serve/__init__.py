from repro.serve.sampling import distributed_topk_sample, topk_logits  # noqa: F401
