from repro.serve.sampling import distributed_topk_sample, topk_logits  # noqa: F401

# the OLAP serving tier (continuous batching over prepared plans) lives in
# repro.serve.olap_engine / repro.serve.workload; imported lazily here so
# `import repro.serve` stays cheap for the sampling-only callers
__all__ = ["distributed_topk_sample", "topk_logits", "OLAPEngine",
           "AdmissionError"]


def __getattr__(name):
    if name in ("OLAPEngine", "AdmissionError"):
        from repro.serve import olap_engine

        return getattr(olap_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
