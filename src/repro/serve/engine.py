"""Batched serving engine: prefill + decode loop with the distributed
top-k sampler at the head.

`make_serve_step` builds the jitted one-token step the decode/long dry-run
cells lower: (params, state, token) -> (next_token, state).  Sampling uses
the §3.2.3 merging reduction over the model axis via shard_map; greedy and
categorical draws share the same top-k core.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import sharding as SH
from repro.serve.sampling import topk_logits


def make_serve_step(model, mesh, *, k: int = 8, greedy: bool = True,
                    rules=None):
    """One decode step with distributed top-k head.  ``rules`` overrides the
    logical-axis mapping (the decode-optimized (data, model_kv, model_b)
    layout passes its own)."""
    cfg = model.cfg
    base_rules = dict(rules or SH.DEFAULT_RULES)

    def serve_step(params, state, token, rng):
        B = token.shape[0]
        logits, state = model.decode_step(params, state, token[:, None])
        # batch sharding degrades to replication when B doesn't divide the
        # dp shards (long_500k: B=1)
        rules_ = dict(base_rules)
        batch_axes = rules_.get("batch")
        batch_axes = (batch_axes,) if isinstance(batch_axes, str) else (
            batch_axes or ())
        shards = 1
        for ax in batch_axes:
            if ax in mesh.axis_names:
                shards *= mesh.shape[ax]
        if B % max(shards, 1):
            rules_["batch"] = None
        rules = rules_
        batch_spec = SH.resolve(("batch",), mesh, rules)[0]
        used = ((batch_spec,) if isinstance(batch_spec, str)
                else tuple(batch_spec or ()))
        model_axes = tuple(a for a in mesh.axis_names
                           if a.startswith("model") and a not in used)
        vspec = model_axes if len(model_axes) > 1 else (
            model_axes[0] if model_axes else None)
        # logits: (B, V) sharded over the model axes on V -> distributed top-k
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, P(batch_spec, vspec))
        )

        def head(local_logits):
            vals, ids = topk_logits(local_logits, k, axis=model_axes)
            if greedy:
                return ids[:, 0]
            draw = jax.random.categorical(rng, vals.astype(jnp.float32), -1)
            return jnp.take_along_axis(ids, draw[:, None], 1)[:, 0]

        if model_axes:
            next_tok = jax.shard_map(
                head,
                mesh=mesh,
                in_specs=P(batch_spec, vspec),
                out_specs=P(batch_spec),
                check_vma=False,
            )(logits)
        else:
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return next_tok, state

    return serve_step


def decode_loop(model, params, state, first_token, steps: int, mesh,
                *, k: int = 8):
    """Host-driven decode loop (the examples use this; production serving
    would run the scan on-device)."""
    step_fn = jax.jit(make_serve_step(model, mesh, k=k))
    toks = [first_token]
    rng = jax.random.key(0)
    for i in range(steps):
        rng, sub = jax.random.split(rng)
        nxt, state = step_fn(params, state, toks[-1], sub)
        toks.append(nxt)
    return jnp.stack(toks, axis=1), state
