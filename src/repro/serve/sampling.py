"""Distributed top-k sampling — the paper's §3.2.3 merging reduction applied
to the decode head.

At decode time the logits row is sharded over the ``model`` axis (vocab
parallelism: 64k–257k entries, 16-way).  The naive head all-gathers the full
row per token (vocab x 4 bytes x batch); instead each rank selects its LOCAL
top-k (a 'local aggregation'), and a log2(P)-depth merging reduction — the
exact §3.2.3 butterfly from repro.core.topk — yields the global top-k, from
which the host (or an argmax/categorical draw) samples.  Bottleneck bytes
drop from O(V) to O(k log P) per token.

The §3.2.5 m-bit idea is available as a first pruning pass (`approx=True`):
ranks exchange 8-bit magnitude codes of their local top-k values first and
fetch exact values only for surviving candidates — for LM logits the win is
small (k is tiny) but the code path mirrors the paper's Q15 and is exercised
by the benchmark.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import topk as topk_mod


def topk_logits(local_logits, k: int, *, axis: str = "model",
                vocab_offset=None):
    """Inside shard_map: local_logits (B, V_local) -> global TopK per row.

    vocab_offset: global id of this rank's first vocab entry (default
    rank * V_local).  Returns (values (B, k), token_ids (B, k)).
    """
    B, Vl = local_logits.shape
    if vocab_offset is None:
        axes = axis if isinstance(axis, (tuple, list)) else (axis,)
        flat = jnp.int32(0)
        for ax in axes:  # row-major over the axis tuple (PartitionSpec order)
            flat = flat * lax.axis_size(ax) + lax.axis_index(ax)
        vocab_offset = flat * Vl
    ids = vocab_offset + jnp.arange(Vl, dtype=jnp.int32)

    local = jax.vmap(lambda row: topk_mod.local_topk(row, ids, k))(local_logits)
    # batched §3.2.3 butterfly: the merge operator runs per batch row
    from repro.core import exchange

    merged = exchange.butterfly_allreduce(
        local, jax.vmap(topk_mod.merge_topk), axis
    )
    return merged.values, merged.keys


def distributed_topk_sample(local_logits, k: int, rng, *, axis: str = "model",
                            temperature: float = 1.0):
    """Top-k sampling over model-sharded logits (inside shard_map).

    Returns (B,) sampled token ids (identical on every rank — the butterfly
    is an ALLreduce, every rank holds the winners)."""
    values, ids = topk_logits(local_logits, k, axis=axis)
    logits = values.astype(jnp.float32) / max(temperature, 1e-6)
    # rng must be identical across ranks for a consistent draw
    choice = jax.random.categorical(rng, logits, axis=-1)
    return jnp.take_along_axis(ids, choice[:, None], axis=1)[:, 0]


def greedy_from_topk(values, ids):
    return ids[:, 0]


def naive_allgather_argmax(local_logits, *, axis: str = "model"):
    """The baseline the paper's §3.2.3 replaces: ship the whole row."""
    full = lax.all_gather(local_logits, axis, axis=1, tiled=True)  # (B, V)
    return jnp.argmax(full, axis=-1).astype(jnp.int32)
