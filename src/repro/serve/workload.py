"""Serving workload + load generators for the continuous-batching tier.

One definition of "a mixed Tier-1/Tier-2/parameterized request stream",
shared by ``launch/serve_olap.py --serve`` and
``benchmarks/serving_load.py`` so the interactive report and the CI gate
measure the same thing:

- ``tier1``  cube-covered serving queries on their on-edge default
  bindings (``repro.tpch.queries.SERVING_QUERIES``) — the microsecond
  router path, the traffic whose tail latency must survive load;
- ``param``  TPC-H §2.4 substitution draws of the parameterized forms
  (``PARAM_QUERIES``; Q6/Q14 by default — the dispatch-bound shapes
  continuous batching helps most), each request a distinct binding of a
  shared prepared shape;
- ``tier2``  the off-edge Q1 variant (``uncovered_query``) — misses every
  cube and runs the compiled SPMD plan.

Every item carries a PREPARED handle (built once per distinct shape), so
a request is "submit this binding", not "re-canonicalize this tree" —
the paper's compile-once serving model.

Two generator disciplines:

- ``run_closed_loop``: N clients, each submitting its next request the
  moment the previous answer lands — measures saturated throughput;
- ``run_open_loop``: Poisson arrivals at a target rate, independent of
  completion — measures latency at a controlled load level (the
  open-vs-closed distinction matters: a closed loop cannot observe
  queueing collapse).

``sequential_baseline`` replays the same items on one synchronous client
(prepared ``execute`` per request) — the pre-engine status quo the
throughput gate compares against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.tpch import queries as tq
from repro.tpch.driver import PreparedQuery

DEFAULT_MIX = {"param": 0.6, "tier1": 0.3, "tier2": 0.1}
PARAM_NAMES = ("q6", "q14_promo")  # dispatch-bound shapes: batching wins


@dataclasses.dataclass
class WorkItem:
    """One request of the stream: a prepared handle plus its binding."""

    kind: str                  # "tier1" | "param" | "tier2"
    name: str                  # query label for reporting
    prep: PreparedQuery
    binding: Optional[dict]    # None -> the prepared defaults


@dataclasses.dataclass
class Completion:
    """One served request: the answer and its client-observed latency."""

    item: WorkItem
    latency_s: float
    answer: object             # QueryAnswer (or the raised exception)
    ok: bool = True


def mixed_workload(driver, n: int, *, seed: int = 0, mix=None,
                   param_names: Sequence[str] = PARAM_NAMES) -> list:
    """Build ``n`` work items in the given kind mix (shuffled, seeded).

    Shapes are prepared once up front; ``param`` items draw random §2.4
    substitution bindings (distinct per request), ``tier1``/``tier2``
    items run their query's default binding.
    """
    rng = np.random.default_rng(seed)
    mix = dict(DEFAULT_MIX if mix is None else mix)
    total = sum(mix.values())

    tier1 = [(name, driver.prepare(make()))
             for name, make in tq.SERVING_QUERIES.items()]
    # keep only the shapes the router actually covers on their defaults —
    # the tier1 class must measure the microsecond path, not a mislabel
    tier1 = [(name, prep) for name, prep in tier1
             if prep.answer_tier1(prep.binding()) is not None]
    if not tier1:
        raise RuntimeError("no cube-covered serving query: call "
                           "driver.build_cubes() before mixed_workload()")
    params = {name: driver.prepare(tq.PARAM_QUERIES[name]())
              for name in param_names}
    tier2 = driver.prepare(tq.uncovered_query())

    kinds = list(mix)
    probs = np.asarray([mix[k] / total for k in kinds])
    items = []
    for i in range(n):
        kind = kinds[int(rng.choice(len(kinds), p=probs))]
        if kind == "tier1":
            name, prep = tier1[int(rng.integers(len(tier1)))]
            items.append(WorkItem("tier1", name, prep, None))
        elif kind == "param":
            name = param_names[int(rng.integers(len(param_names)))]
            items.append(WorkItem("param", name, params[name],
                                  tq.random_binding(name, rng)))
        elif kind == "tier2":
            items.append(WorkItem("tier2", "q1_offedge", tier2, None))
        else:
            raise ValueError(f"unknown workload kind {kind!r}")
    return items


def warm_workload(driver, items, *, batch_sizes=()) -> None:
    """Pay every XLA compile up front so a load run measures steady-state
    serving, not compilation: one scalar execute per distinct shape, plus
    one batched execute per (parameterized shape, lane count) in
    ``batch_sizes`` — the padded bucket sizes the engine will dispatch."""
    seen = {}
    for it in items:
        seen.setdefault(it.prep.shape_key, it)
    for it in seen.values():
        it.prep.execute(it.binding)
        if it.prep.params:
            for b in batch_sizes:
                if b > 1:
                    rows = [it.binding or {}] * b
                    it.prep.execute_batch(rows)


# -- generators -------------------------------------------------------------


async def run_closed_loop(engine, items, *, clients: int = 8) -> list:
    """N clients, each submitting its next item as soon as the previous
    completes.  Returns one :class:`Completion` per item, in item order."""
    import asyncio

    results = [None] * len(items)
    queue = list(enumerate(items))
    pos = 0

    async def client():
        nonlocal pos
        while pos < len(queue):
            idx, item = queue[pos]
            pos += 1
            results[idx] = await _submit_one(engine, item)

    await asyncio.gather(*[client() for _ in range(max(1, clients))])
    return results


async def run_open_loop(engine, items, *, rate_qps: float,
                        seed: int = 0) -> list:
    """Poisson arrivals at ``rate_qps``: each item is launched at its
    arrival time whether or not earlier requests finished (the open-loop
    discipline that can actually observe queueing delay)."""
    import asyncio

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, size=len(items))
    tasks = []
    for item, gap in zip(items, gaps):
        tasks.append(asyncio.ensure_future(_submit_one(engine, item)))
        await asyncio.sleep(float(gap))
    return list(await asyncio.gather(*tasks))


async def _submit_one(engine, item) -> Completion:
    t0 = time.perf_counter()
    try:
        ans = await engine.submit(item.prep, item.binding)
    except Exception as e:  # admission rejects land in the report, not up
        return Completion(item, time.perf_counter() - t0, e, ok=False)
    return Completion(item, time.perf_counter() - t0, ans)


def sequential_baseline(driver, items) -> list:
    """The pre-engine serving model: ONE synchronous client, prepared
    ``execute`` per request, no coalescing.  Same Completion schema as
    the generators so reports and parity checks share code."""
    out = []
    for item in items:
        t0 = time.perf_counter()
        ans = item.prep.execute(item.binding)
        out.append(Completion(item, time.perf_counter() - t0, ans))
    return out


# -- reporting --------------------------------------------------------------


def percentile(xs, q: float) -> float:
    """Exact order-statistic percentile (the load reports gate on tails,
    so no log-bucket approximation here)."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def summarize(completions, wall_s: float) -> dict:
    """Per-kind latency percentiles + overall sustained q/s."""
    ok = [c for c in completions if c.ok]
    by_kind = {}
    for c in ok:
        by_kind.setdefault(c.item.kind, []).append(c.latency_s)
    out = {
        "requests": len(completions),
        "failed": len(completions) - len(ok),
        "wall_s": wall_s,
        "qps": len(ok) / wall_s if wall_s > 0 else 0.0,
        "kinds": {},
    }
    for kind, lats in sorted(by_kind.items()):
        out["kinds"][kind] = {
            "n": len(lats),
            "p50_ms": percentile(lats, 0.50) * 1e3,
            "p95_ms": percentile(lats, 0.95) * 1e3,
            "p99_ms": percentile(lats, 0.99) * 1e3,
            "mean_ms": sum(lats) / len(lats) * 1e3,
        }
    return out
