"""repro — JAX/Pallas reproduction of "Fast OLAP Query Execution in Main
Memory on Large Data in a Cluster".

Importing any submodule installs the JAX version-compat shims (see
``repro.compat``) so the code runs on both current and 0.4.x JAX APIs.
"""
from repro import compat as _compat

_compat.install()
